//! Tests of the sharded, evicting service core: concurrent-client
//! soak through the shard router, LRU eviction of all three plan
//! stores, per-client admission quota, bounded metrics reservoirs, the
//! shutdown-latency fix, counter-after-validation ordering, the algo
//! whitelist (`tc_ec` served on all four routes, unknown algos fail
//! fast without touching a counter), and the bounded TCP worker pool
//! with pipelining. All over the interpreter backend (no artifacts on
//! disk required).

use std::sync::{Arc, OnceLock};
use std::time::{Duration, Instant};

use tcfft::coordinator::{FftRequest, FftService, Op, Server, ServiceConfig};
use tcfft::error::{relative_error, relative_rmse, TcFftError};
use tcfft::fft::{mixed, radix2};
use tcfft::hp::{C32, C64};
use tcfft::plan::Direction;
use tcfft::runtime::{PlanarBatch, Runtime};
use tcfft::workload::random_signal;

fn shared_runtime() -> &'static Arc<Runtime> {
    static RT: OnceLock<Arc<Runtime>> = OnceLock::new();
    RT.get_or_init(|| {
        Arc::new(Runtime::load_default().expect("runtime must load without artifacts"))
    })
}

fn service_with(cfg: ServiceConfig) -> Arc<FftService> {
    Arc::new(FftService::start(Arc::clone(shared_runtime()), cfg))
}

fn service() -> Arc<FftService> {
    service_with(ServiceConfig::default())
}

fn widen(x: &[C32]) -> Vec<C64> {
    x.iter().map(|c| C64::new(c.re as f64, c.im as f64)).collect()
}

fn fwd_req(n: usize, sig: &[C32]) -> FftRequest {
    FftRequest {
        op: Op::Fft1d { n },
        algo: "tc".into(),
        direction: Direction::Forward,
        input: PlanarBatch::from_complex(sig, vec![n]),
    }
}

/// Submit one forward complex request and check the reply against the
/// mixed-radix oracle.
fn check_fft1d(svc: &FftService, client: u64, n: usize, seed: u64) {
    let sig = random_signal(n, seed);
    let out = svc.submit_as(client, fwd_req(n, &sig)).unwrap().wait().unwrap();
    let q = PlanarBatch::from_complex(&sig, vec![1, n]).quantize_f16();
    let want = mixed::fft_mixed_batch(&widen(&q.to_complex()), 1, n, false);
    let err = relative_error(&want, &widen(&out.to_complex()));
    assert!(err < 5e-3, "client {client} n={n}: err {err}");
}

/// Submit one forward R2C request and check the packed reply.
fn check_rfft1d(svc: &FftService, client: u64, n: usize, seed: u64) {
    let bins = n / 2 + 1;
    let sig: Vec<f32> = random_signal(n, seed).iter().map(|c| c.re).collect();
    let out = svc
        .submit_as(
            client,
            FftRequest {
                op: Op::Rfft1d { n },
                algo: "tc".into(),
                direction: Direction::Forward,
                input: PlanarBatch::from_real(&sig, vec![n]),
            },
        )
        .unwrap()
        .wait()
        .unwrap();
    assert_eq!(out.shape, vec![1, bins]);
    let q = PlanarBatch::from_real(&sig, vec![1, n]).quantize_f16();
    let want = mixed::fft_mixed_batch(&widen(&q.to_complex()), 1, n, false);
    let rmse = relative_rmse(&want[..bins], &widen(&out.to_complex()));
    assert!(rmse < 5e-3, "client {client} rfft n={n}: rmse {rmse:.3e}");
}

#[test]
fn soak_64_concurrent_clients_through_the_shard_router() {
    // 64 client threads, mixed ops, every reply checked against its
    // oracle row — the router must never cross rows between shards,
    // steal-drained batches included
    let svc = service();
    assert!(svc.shards() >= 2, "default config must actually shard");
    let per_client = 4;
    let handles: Vec<_> = (0..64u64)
        .map(|c| {
            let svc = Arc::clone(&svc);
            std::thread::spawn(move || {
                for i in 0..per_client {
                    let seed = c * 1000 + i;
                    match (c + i) % 3 {
                        0 => check_fft1d(&svc, c, 1024, seed),
                        1 => check_fft1d(&svc, c, 4096, seed),
                        _ => check_rfft1d(&svc, c, 1024, seed),
                    }
                }
            })
        })
        .collect();
    for h in handles {
        h.join().expect("client thread panicked");
    }
    let snap = svc.metrics().snapshot();
    let total = 64 * per_client as i64;
    assert_eq!(snap.get("completed").unwrap().as_i64(), Some(total));
    assert_eq!(snap.get("requests").unwrap().as_i64(), Some(total));
    assert_eq!(snap.get("failed").unwrap().as_i64(), Some(0));
    assert_eq!(snap.get("rejected").unwrap().as_i64(), Some(0));
    svc.shutdown();
}

#[test]
fn direct_plan_cache_stays_within_budget_under_key_walk() {
    // a client walking (n, dir) space must not grow the plan cache
    // past its byte budget — entries evict and every request still
    // completes (plans rebuild from the registry transparently)
    let svc = service_with(ServiceConfig {
        plan_cache_bytes: 4096, // holds a few plan metadata entries
        ..ServiceConfig::default()
    });
    for n in [256usize, 512, 1024, 2048, 4096] {
        for dir in [Direction::Forward, Direction::Inverse] {
            let sig = random_signal(n, n as u64);
            let t = svc
                .submit(FftRequest {
                    op: Op::Fft1d { n },
                    algo: "tc".into(),
                    direction: dir,
                    input: PlanarBatch::from_complex(&sig, vec![n]),
                })
                .unwrap();
            t.wait().unwrap();
            let m = svc.metrics();
            assert!(
                m.plan_cache.bytes() <= 4096,
                "plan cache {} bytes over the 4096 budget",
                m.plan_cache.bytes()
            );
        }
    }
    let m = svc.metrics();
    assert!(
        m.plan_cache.evictions() > 0,
        "10 distinct plans through a few-entry budget must evict"
    );
    assert_eq!(
        svc.metrics().snapshot().get("completed").unwrap().as_i64(),
        Some(10)
    );
    svc.shutdown();
}

#[test]
fn evicted_large_plan_is_rebuilt_transparently_on_resubmit() {
    // budget sized to hold EITHER the complex 2^18 four-step plan
    // (~6.3 MB) OR the real one (~5.8 MB), not both: the second build
    // evicts the first, and resubmitting the first kind must rebuild
    // it transparently with a correct result
    let svc = service_with(ServiceConfig {
        large_cache_bytes: 10 << 20,
        ..ServiceConfig::default()
    });
    let n = 1 << 18;

    let run_complex = |seed: u64| {
        let sig = random_signal(n, seed);
        let out = svc.submit(fwd_req(n, &sig)).unwrap().wait().unwrap();
        let q = PlanarBatch::from_complex(&sig, vec![1, n]).quantize_f16();
        let want = radix2::fft_vec(&widen(&q.to_complex()), false);
        let rmse = relative_rmse(&want, &widen(&out.to_complex()));
        assert!(rmse <= 5e-3, "four-step rel-RMSE {rmse:.3e}");
    };
    run_complex(1);
    let m = svc.metrics();
    assert_eq!(m.large_cache.entries(), 1);
    assert!(m.large_cache.bytes() <= 10 << 20);

    // the real 2^18 plan lands on a different key and evicts the
    // complex one (both don't fit in 10 MB)
    check_rfft1d(&svc, 0, n, 2);
    let m = svc.metrics();
    assert!(
        m.large_cache.evictions() >= 1,
        "second large plan must evict the first"
    );
    assert!(m.large_cache.bytes() <= 10 << 20);

    // resubmit the complex transform: cache miss, transparent rebuild,
    // same deterministic fingerprint key, correct result
    run_complex(3);
    let m = svc.metrics();
    assert!(m.large_cache.bytes() <= 10 << 20);
    assert!(m.large_cache.evictions() >= 2);
    assert_eq!(svc.metrics().snapshot().get("failed").unwrap().as_i64(), Some(0));
    svc.shutdown();
}

#[test]
fn eviction_racing_a_queued_batch_rebuilds_at_execution_time() {
    // a request is parked in its queue while its plan gets evicted by
    // a competing build; the executor must rebuild the plan from the
    // queue key instead of failing the batch (`large_rebuilds` counts)
    let svc = service_with(ServiceConfig {
        large_cache_bytes: 10 << 20,
        max_wait: Duration::from_secs(3600), // requests park until shutdown
        inline_exec: false,                  // the submitter must not execute
        ..ServiceConfig::default()
    });
    let n = 1 << 18;
    let sig = random_signal(n, 11);
    let t_complex = svc.submit(fwd_req(n, &sig)).unwrap();

    // competing real-plan build evicts the (cached, but in-queue-use)
    // complex plan
    let rsig: Vec<f32> = random_signal(n, 12).iter().map(|c| c.re).collect();
    let t_real = svc
        .submit(FftRequest {
            op: Op::Rfft1d { n },
            algo: "tc".into(),
            direction: Direction::Forward,
            input: PlanarBatch::from_real(&rsig, vec![n]),
        })
        .unwrap();
    assert!(svc.metrics().large_cache.evictions() >= 1);

    // shutdown force-drains both queues through the exec workers
    svc.shutdown();
    let out = t_complex.wait().unwrap();
    let q = PlanarBatch::from_complex(&sig, vec![1, n]).quantize_f16();
    let want = radix2::fft_vec(&widen(&q.to_complex()), false);
    let rmse = relative_rmse(&want, &widen(&out.to_complex()));
    assert!(rmse <= 5e-3, "rebuilt-plan rel-RMSE {rmse:.3e}");
    let out = t_real.wait().unwrap();
    assert_eq!(out.shape, vec![1, n / 2 + 1]);

    let m = svc.metrics();
    assert!(
        m.large_rebuilds.load(std::sync::atomic::Ordering::Relaxed) >= 1,
        "at least one batch must have rebuilt its evicted plan at exec time"
    );
    assert_eq!(svc.metrics().snapshot().get("failed").unwrap().as_i64(), Some(0));
}

/// Round-trip one real image through the service's large-2D route:
/// forward vs the f64 2D oracle on the packed bins, then the packed
/// spectrum pre-scaled by 1/(nx*ny) (the unnormalized inverse would
/// overflow fp16 at these sizes) back through the inverse route.
fn check_large_rfft2d_round_trip(svc: &FftService, nx: usize, ny: usize, seed: u64) {
    let bins = ny / 2 + 1;
    let sig: Vec<f32> = random_signal(nx * ny, seed).iter().map(|c| c.re).collect();
    let input = PlanarBatch::from_real(&sig, vec![1, nx, ny]);
    let spec = svc
        .rfft2d_blocking(input.clone(), "tc", Direction::Forward)
        .unwrap();
    assert_eq!(spec.shape, vec![1, nx, bins]);

    let q = widen(&input.quantize_f16().to_complex());
    let full = tcfft::fft::oracle2d(&q, nx, ny, false);
    let want: Vec<C64> = (0..nx)
        .flat_map(|r| full[r * ny..r * ny + bins].to_vec())
        .collect();
    let rmse = relative_rmse(&want, &widen(&spec.to_complex()));
    assert!(rmse < 5e-3, "{nx}x{ny} forward: packed rel-RMSE {rmse:.3e}");

    let mut scaled = spec;
    let scale = (nx * ny) as f32;
    for v in scaled.re.iter_mut().chain(scaled.im.iter_mut()) {
        *v /= scale;
    }
    let back = svc
        .rfft2d_blocking(scaled, "tc", Direction::Inverse)
        .unwrap();
    assert_eq!(back.shape, vec![1, nx, ny]);
    let qin = input.quantize_f16();
    let mut num = 0.0f64;
    let mut den = 0.0f64;
    for i in 0..nx * ny {
        let d = back.re[i] as f64 - qin.re[i] as f64;
        num += d * d;
        den += (qin.re[i] as f64) * (qin.re[i] as f64);
        assert_eq!(back.im[i], 0.0, "C2R output must be real");
    }
    let rt_rmse = (num / den).sqrt();
    assert!(rt_rmse < 1e-2, "{nx}x{ny} round trip: rmse {rt_rmse:.3e}");
}

#[test]
fn large_2d_route_round_trips_at_2048x2048() {
    // the acceptance workload: beyond the 256x256 catalog ladder, the
    // service routes rfft2d/irfft2d to the cached Plan2d composition
    let svc = service_with(ServiceConfig {
        request_deadline: None, // the 4M-point debug-build run may be slow
        ..ServiceConfig::default()
    });
    check_large_rfft2d_round_trip(&svc, 2048, 2048, 0x2D48);
    let m = svc.metrics();
    assert_eq!(m.large_cache.entries(), 2, "forward and inverse Plan2d cached");
    let snap = svc.metrics().snapshot();
    assert_eq!(snap.get("rfft2d_requests").unwrap().as_i64(), Some(2));
    assert_eq!(snap.get("large_requests").unwrap().as_i64(), Some(2));
    assert_eq!(snap.get("failed").unwrap().as_i64(), Some(0));
    svc.shutdown();
}

#[test]
fn large_2d_route_round_trips_rectangular() {
    let svc = service_with(ServiceConfig {
        request_deadline: None,
        ..ServiceConfig::default()
    });
    check_large_rfft2d_round_trip(&svc, 512, 2048, 0x2D49);
    assert_eq!(
        svc.metrics().snapshot().get("failed").unwrap().as_i64(),
        Some(0)
    );
    svc.shutdown();
}

#[test]
fn eviction_racing_a_queued_2d_batch_rebuilds_at_execution_time() {
    // the 2D analogue of the 1D race above: a parked rfft2d batch loses
    // its Plan2d to a competing build and the executor must rebuild it
    // from the `4step2d:{nx}x{ny}:{algo}:{dir}` queue key
    let (nx, ny) = (512usize, 512usize);
    let bins = ny / 2 + 1;
    let svc = service_with(ServiceConfig {
        // holds either 512x512 Plan2d (~1.1 MB, panel-dominated) but
        // not both directions at once
        large_cache_bytes: 3 << 19,
        max_wait: Duration::from_secs(3600), // requests park until shutdown
        inline_exec: false,                  // the submitter must not execute
        request_deadline: None,
        ..ServiceConfig::default()
    });
    let sig: Vec<f32> = random_signal(nx * ny, 21).iter().map(|c| c.re).collect();
    let input = PlanarBatch::from_real(&sig, vec![nx, ny]);
    let t_fwd = svc
        .submit(FftRequest {
            op: Op::Rfft2d { nx, ny },
            algo: "tc".into(),
            direction: Direction::Forward,
            input: input.clone(),
        })
        .unwrap();

    // competing inverse-plan build evicts the parked forward plan
    let mut spec = PlanarBatch::new(vec![nx, bins]);
    for (k, v) in spec.re.iter_mut().enumerate() {
        *v = ((k * 13 + 5) % 37) as f32 / 37.0 - 0.5;
    }
    let t_inv = svc
        .submit(FftRequest {
            op: Op::Rfft2d { nx, ny },
            algo: "tc".into(),
            direction: Direction::Inverse,
            input: spec,
        })
        .unwrap();
    assert!(svc.metrics().large_cache.evictions() >= 1);

    // shutdown force-drains both queues through the exec workers
    svc.shutdown();
    let out = t_fwd.wait().unwrap();
    assert_eq!(out.shape, vec![1, nx, bins]);
    let q = widen(&PlanarBatch { shape: vec![1, nx, ny], ..input }.quantize_f16().to_complex());
    let full = tcfft::fft::oracle2d(&q, nx, ny, false);
    let want: Vec<C64> = (0..nx)
        .flat_map(|r| full[r * ny..r * ny + bins].to_vec())
        .collect();
    let rmse = relative_rmse(&want, &widen(&out.to_complex()));
    assert!(rmse <= 5e-3, "rebuilt-Plan2d rel-RMSE {rmse:.3e}");
    let out = t_inv.wait().unwrap();
    assert_eq!(out.shape, vec![1, nx, ny]);

    let m = svc.metrics();
    assert!(
        m.large_rebuilds.load(std::sync::atomic::Ordering::Relaxed) >= 1,
        "at least one 2D batch must have rebuilt its evicted plan at exec time"
    );
    assert_eq!(svc.metrics().snapshot().get("failed").unwrap().as_i64(), Some(0));
}

#[test]
fn rfft2d_fail_fast_names_catalog_and_large_route_limits() {
    // sizes neither the catalog nor the large-2D route serves must fail
    // fast with the stable `no_artifact` code and a message naming BOTH
    // sets of bounds — and leave every counter untouched
    let svc = service();
    for (nx, ny) in [(4096usize, 8usize), (16384, 16384)] {
        let err = svc
            .submit(FftRequest {
                op: Op::Rfft2d { nx, ny },
                algo: "tc".into(),
                direction: Direction::Forward,
                input: PlanarBatch::new(vec![nx.min(64), ny.min(64)]),
            })
            .unwrap_err();
        assert_eq!(err.code(), "no_artifact", "{err}");
        let msg = err.to_string();
        assert!(msg.contains("8x8..256x256"), "catalog bounds missing: {msg}");
        assert!(msg.contains("512..16384"), "large-route bounds missing: {msg}");
        assert!(msg.contains("max_large_n"), "area guard missing: {msg}");
    }
    let snap = svc.metrics().snapshot();
    assert_eq!(snap.get("requests").unwrap().as_i64(), Some(0));
    assert_eq!(snap.get("rfft2d_requests").unwrap().as_i64(), Some(0));
    svc.shutdown();
}

#[test]
fn tc_ec_is_served_on_all_four_routes() {
    // the error-corrected tier must be admitted everywhere an algo
    // string is whitelisted: direct catalog artifacts, the large-1D
    // four-step route, the large-2D Plan2d route, and filter-bank
    // registration — each reply checked against its oracle
    let svc = service_with(ServiceConfig {
        request_deadline: None, // debug-build large runs may be slow
        ..ServiceConfig::default()
    });

    // 1. direct catalog route (n=1024 has a tc_ec artifact)
    let n = 1024;
    let sig = random_signal(n, 0xEC1);
    let out = svc
        .submit(FftRequest {
            op: Op::Fft1d { n },
            algo: "tc_ec".into(),
            direction: Direction::Forward,
            input: PlanarBatch::from_complex(&sig, vec![n]),
        })
        .unwrap()
        .wait()
        .unwrap();
    let q = PlanarBatch::from_complex(&sig, vec![1, n]).quantize_f16();
    let want = mixed::fft_mixed_batch(&widen(&q.to_complex()), 1, n, false);
    let rmse = relative_rmse(&want, &widen(&out.to_complex()));
    assert!(rmse < 5e-3, "direct tc_ec: rmse {rmse:.3e}");

    // 2. large-1D four-step route (2^18 exceeds the catalog)
    let n = 1 << 18;
    let sig = random_signal(n, 0xEC2);
    let out = svc
        .submit(FftRequest {
            op: Op::Fft1d { n },
            algo: "tc_ec".into(),
            direction: Direction::Forward,
            input: PlanarBatch::from_complex(&sig, vec![n]),
        })
        .unwrap()
        .wait()
        .unwrap();
    let q = PlanarBatch::from_complex(&sig, vec![1, n]).quantize_f16();
    let want = radix2::fft_vec(&widen(&q.to_complex()), false);
    let rmse = relative_rmse(&want, &widen(&out.to_complex()));
    assert!(rmse < 5e-3, "large-1D tc_ec: rmse {rmse:.3e}");

    // 3. large-2D route (512x512 is beyond the 256x256 catalog ladder)
    let (nx, ny) = (512usize, 512usize);
    let bins = ny / 2 + 1;
    let rsig: Vec<f32> = random_signal(nx * ny, 0xEC3).iter().map(|c| c.re).collect();
    let input = PlanarBatch::from_real(&rsig, vec![1, nx, ny]);
    let spec = svc
        .rfft2d_blocking(input.clone(), "tc_ec", Direction::Forward)
        .unwrap();
    assert_eq!(spec.shape, vec![1, nx, bins]);
    let q = widen(&input.quantize_f16().to_complex());
    let full = tcfft::fft::oracle2d(&q, nx, ny, false);
    let want: Vec<C64> = (0..nx)
        .flat_map(|r| full[r * ny..r * ny + bins].to_vec())
        .collect();
    let rmse = relative_rmse(&want, &widen(&spec.to_complex()));
    assert!(rmse < 5e-3, "large-2D tc_ec: rmse {rmse:.3e}");

    // 4. filter-bank registration and convolve
    let n = 256;
    svc.register_filter_bank("ec-bank", n, &[vec![1.0f32, 0.5, 0.25]], "tc_ec")
        .unwrap();
    let rsig: Vec<f32> = random_signal(n, 0xEC4).iter().map(|c| c.re).collect();
    let out = svc
        .submit_convolve("ec-bank", PlanarBatch::from_real(&rsig, vec![n]))
        .unwrap()
        .wait()
        .unwrap();
    assert_eq!(out.shape, vec![1, 1, n]);

    assert_eq!(svc.metrics().snapshot().get("failed").unwrap().as_i64(), Some(0));
    svc.shutdown();
}

#[test]
fn unknown_algo_fails_fast_with_no_artifact_on_every_route() {
    // a typo'd algo — e.g. from the TCP surface — must be refused
    // before any plan cache key is minted and before any counter
    // moves, with the stable `no_artifact` code on every route
    let svc = service();
    let n = 1024;
    let sig = random_signal(n, 0xBAD);
    let err = svc
        .submit(FftRequest {
            op: Op::Fft1d { n },
            algo: "tc_magic".into(),
            direction: Direction::Forward,
            input: PlanarBatch::from_complex(&sig, vec![n]),
        })
        .unwrap_err();
    assert_eq!(err.code(), "no_artifact", "direct route: {err}");

    let big = 1 << 18;
    let err = svc
        .submit(FftRequest {
            op: Op::Fft1d { n: big },
            algo: "tc_magic".into(),
            direction: Direction::Forward,
            input: PlanarBatch::new(vec![big]),
        })
        .unwrap_err();
    assert_eq!(err.code(), "no_artifact", "large-1D route: {err}");

    let err = svc
        .submit(FftRequest {
            op: Op::Rfft2d { nx: 512, ny: 512 },
            algo: "tc_magic".into(),
            direction: Direction::Forward,
            input: PlanarBatch::new(vec![512, 512]),
        })
        .unwrap_err();
    assert_eq!(err.code(), "no_artifact", "large-2D route: {err}");

    let err = svc
        .register_filter_bank("magic", 256, &[vec![1.0f32, 0.5]], "tc_magic")
        .unwrap_err();
    assert_eq!(err.code(), "no_artifact", "filter-bank route: {err}");

    let snap = svc.metrics().snapshot();
    for k in [
        "requests",
        "rfft_requests",
        "rfft2d_requests",
        "large_requests",
        "completed",
        "failed",
    ] {
        assert_eq!(snap.get(k).unwrap().as_i64(), Some(0), "counter '{k}' inflated");
    }
    svc.shutdown();
}

#[test]
fn bank_cache_honors_its_byte_budget_under_racing_registrations() {
    let budget = 16 << 10; // a handful of small banks
    let svc = service_with(ServiceConfig {
        bank_cache_bytes: budget,
        ..ServiceConfig::default()
    });
    let n = 256;
    let handles: Vec<_> = (0..4)
        .map(|t| {
            let svc = Arc::clone(&svc);
            std::thread::spawn(move || {
                for i in 0..3 {
                    let taps = vec![1.0f32, 0.5 + t as f32, i as f32 * 0.25];
                    svc.register_filter_bank(&format!("bank-{t}-{i}"), n, &[taps], "tc")
                        .expect("each bank fits the budget alone");
                }
            })
        })
        .collect();
    for h in handles {
        h.join().expect("registering thread panicked");
    }
    let m = svc.metrics();
    assert!(
        m.bank_cache.bytes() <= budget as u64,
        "bank cache {} bytes over the {budget} budget",
        m.bank_cache.bytes()
    );
    assert!(
        m.bank_cache.evictions() > 0,
        "12 banks through a {budget}-byte budget must evict"
    );
    // an evicted bank re-registers cleanly (idempotent recovery), and
    // convolving through it works end to end
    let taps = vec![1.0f32, 0.5, 0.0];
    svc.register_filter_bank("bank-0-0", n, &[taps], "tc").unwrap();
    let sig: Vec<f32> = random_signal(n, 9).iter().map(|c| c.re).collect();
    let out = svc
        .submit_convolve("bank-0-0", PlanarBatch::from_real(&sig, vec![n]))
        .unwrap()
        .wait()
        .unwrap();
    assert_eq!(out.shape, vec![1, 1, n]);
    assert!(m.bank_cache.bytes() <= budget as u64);
    svc.shutdown();
}

#[test]
fn per_client_quota_rejects_bursts_independently() {
    let svc = service_with(ServiceConfig {
        quota_rate: 1e-9, // effectively no refill within the test
        quota_burst: 3.0,
        ..ServiceConfig::default()
    });
    let n = 1024;
    let mut ok = 0;
    let mut rejected = 0;
    let mut tickets = Vec::new();
    for i in 0..5 {
        let sig = random_signal(n, i);
        match svc.submit_as(7, fwd_req(n, &sig)) {
            Ok(t) => {
                ok += 1;
                tickets.push(t);
            }
            Err(e) => {
                assert!(
                    matches!(e, TcFftError::QuotaExceeded),
                    "expected QuotaExceeded, got: {e}"
                );
                rejected += 1;
            }
        }
    }
    assert_eq!((ok, rejected), (3, 2), "burst of 3 admits exactly 3 of 5");
    // a different client has its own bucket
    let sig = random_signal(n, 99);
    tickets.push(svc.submit_as(8, fwd_req(n, &sig)).unwrap());
    for t in tickets {
        t.wait().unwrap();
    }
    let snap = svc.metrics().snapshot();
    // quota rejections never reach routing: they are counted apart
    // from `requests`, and nothing was queued for them
    assert_eq!(snap.get("quota_rejected").unwrap().as_i64(), Some(2));
    assert_eq!(snap.get("requests").unwrap().as_i64(), Some(4));
    assert_eq!(snap.get("completed").unwrap().as_i64(), Some(4));
    // unmetered in-process submits bypass the gate entirely
    let sig = random_signal(n, 100);
    svc.submit(fwd_req(n, &sig)).unwrap().wait().unwrap();
    svc.shutdown();
}

#[test]
fn metrics_reservoirs_stay_bounded_at_service_level() {
    let svc = service_with(ServiceConfig {
        metrics_reservoir: 16,
        ..ServiceConfig::default()
    });
    let n = 256;
    for i in 0..40 {
        let sig = random_signal(n, i);
        svc.submit(fwd_req(n, &sig)).unwrap().wait().unwrap();
    }
    let snap = svc.metrics().snapshot();
    assert_eq!(snap.get("completed").unwrap().as_i64(), Some(40));
    assert_eq!(
        snap.get("latency_samples").unwrap().as_i64(),
        Some(16),
        "reservoir must cap held samples at the configured capacity"
    );
    assert_eq!(
        snap.get("latency_total").unwrap().as_i64(),
        Some(40),
        "lifetime sample count must still cover every request"
    );
    svc.shutdown();
}

#[test]
fn shutdown_returns_promptly_from_an_idle_park() {
    // flushers park up to park_cap between deadline scans; shutdown
    // must notify them out of the park instead of waiting it out (the
    // pre-shard service set the flag without notifying)
    let svc = service_with(ServiceConfig {
        park_cap: Duration::from_millis(500),
        ..ServiceConfig::default()
    });
    // let every flusher reach its (empty-queue) park
    std::thread::sleep(Duration::from_millis(100));
    let t0 = Instant::now();
    svc.shutdown();
    let took = t0.elapsed();
    assert!(
        took < Duration::from_millis(250),
        "shutdown took {took:?}; flushers must be notified out of a {:?} park",
        Duration::from_millis(500)
    );
}

#[test]
fn counters_move_only_after_validation() {
    // a malformed request must leave every counter untouched: count
    // only what was actually routed and queued (regression: counters
    // used to increment before the shape check)
    let svc = service();
    let r = svc.submit(FftRequest {
        op: Op::Fft1d { n: 1024 },
        algo: "tc".into(),
        direction: Direction::Forward,
        input: PlanarBatch::new(vec![512]), // wrong tail for n=1024
    });
    assert!(r.is_err());
    let r = svc.submit(FftRequest {
        op: Op::Rfft1d { n: 1024 },
        algo: "tc".into(),
        direction: Direction::Forward,
        input: PlanarBatch::new(vec![100]), // wrong tail for rfft 1024
    });
    assert!(r.is_err());
    let snap = svc.metrics().snapshot();
    assert_eq!(snap.get("requests").unwrap().as_i64(), Some(0));
    assert_eq!(snap.get("rfft_requests").unwrap().as_i64(), Some(0));
    assert_eq!(snap.get("rfft2d_requests").unwrap().as_i64(), Some(0));
    assert_eq!(snap.get("large_requests").unwrap().as_i64(), Some(0));
    // a valid request after the failures counts normally
    let sig = random_signal(1024, 5);
    svc.submit(fwd_req(1024, &sig)).unwrap().wait().unwrap();
    let snap = svc.metrics().snapshot();
    assert_eq!(snap.get("requests").unwrap().as_i64(), Some(1));
    svc.shutdown();
}

#[test]
fn shutdown_drains_queued_requests_and_rejects_new_submits() {
    // requests parked in a never-due queue when shutdown() lands must
    // be force-drained and answered — and anything submitted after the
    // flag flips gets a prompt coded ShuttingDown, never a hang
    let svc = service_with(ServiceConfig {
        inline_exec: false,
        max_wait: Duration::from_secs(3600), // batches park until shutdown
        ..ServiceConfig::default()
    });
    let n = 256;
    svc.register_filter_bank("drain", n, &[vec![1.0f32, 0.5]], "tc").unwrap();
    let sig: Vec<f32> = random_signal(n, 3).iter().map(|c| c.re).collect();
    let tickets: Vec<_> = (0..2)
        .map(|_| {
            svc.submit_convolve("drain", PlanarBatch::from_real(&sig, vec![n]))
                .unwrap()
        })
        .collect();
    svc.shutdown();
    for t in tickets {
        let out = t
            .wait_timeout(Duration::from_secs(10))
            .expect("queued requests must be drained and answered by shutdown");
        assert_eq!(out.shape, vec![1, 1, n]);
    }
    match svc.submit_convolve("drain", PlanarBatch::from_real(&sig, vec![n])) {
        Err(TcFftError::ShuttingDown) => {}
        other => panic!("post-shutdown submit must be ShuttingDown, got {other:?}"),
    }
    assert!(svc.metrics().errors_for("shutting_down") >= 1);
    // idempotent: a second shutdown must return immediately
    svc.shutdown();
}

#[test]
fn drop_with_requests_in_flight_joins_cleanly() {
    // dropping the service (no explicit shutdown) with parked requests
    // must run the same drain: every outstanding ticket resolves, and
    // Drop joins every thread — flushers, supervisor, exec workers —
    // without wedging
    let svc = FftService::start(
        Arc::clone(shared_runtime()),
        ServiceConfig {
            inline_exec: false,
            max_wait: Duration::from_secs(3600),
            ..ServiceConfig::default()
        },
    );
    let n = 1024;
    let sig = random_signal(n, 17);
    let tickets: Vec<_> = (0..3).map(|_| svc.submit(fwd_req(n, &sig)).unwrap()).collect();
    let t0 = Instant::now();
    drop(svc);
    assert!(
        t0.elapsed() < Duration::from_secs(10),
        "drop must join, not wedge ({:?})",
        t0.elapsed()
    );
    // tickets outlive the service; after Drop each has its reply
    // already buffered (drained batch) — recv must not block
    for t in tickets {
        t.wait_timeout(Duration::from_millis(100))
            .expect("drained reply must be waiting in the channel after drop");
    }
}

#[test]
fn server_stops_with_an_idle_connection_open() {
    // an idle client used to pin its handler thread in a blocking
    // read forever; with read timeouts the server must join promptly
    let svc = service();
    let server = Server::bind("127.0.0.1:0", Arc::clone(&svc)).unwrap();
    let addr = server.local_addr().unwrap();
    let stop = server.stop_handle();
    let run = std::thread::spawn(move || server.run());

    let conn = std::net::TcpStream::connect(addr).unwrap();
    // the connection says nothing at all; give a worker time to adopt it
    std::thread::sleep(Duration::from_millis(150));
    stop.store(true, std::sync::atomic::Ordering::SeqCst);

    let (done_tx, done_rx) = std::sync::mpsc::channel();
    std::thread::spawn(move || {
        let _ = done_tx.send(run.join());
    });
    done_rx
        .recv_timeout(Duration::from_secs(2))
        .expect("server.run() must return despite the idle connection")
        .unwrap()
        .unwrap();
    drop(conn);
    svc.shutdown();
}

#[test]
fn pipelined_requests_get_replies_in_order() {
    use std::io::{BufRead, BufReader, Write};
    let svc = service();
    let server = Server::bind("127.0.0.1:0", Arc::clone(&svc)).unwrap();
    let addr = server.local_addr().unwrap();
    let stop = server.stop_handle();
    let run = std::thread::spawn(move || server.run());

    let mut conn = std::net::TcpStream::connect(addr).unwrap();
    // three requests written back-to-back before reading any reply;
    // n marks each request so reply order is observable
    let mut expected = Vec::new();
    let mut batch = String::new();
    for n in [256usize, 512, 1024] {
        let sig = random_signal(n, n as u64);
        let re: Vec<String> = sig.iter().map(|c| format!("{:.4}", c.re)).collect();
        let im: Vec<String> = sig.iter().map(|c| format!("{:.4}", c.im)).collect();
        batch.push_str(&format!(
            "{{\"op\":\"fft1d\",\"n\":{n},\"re\":[{}],\"im\":[{}]}}\n",
            re.join(","),
            im.join(",")
        ));
        expected.push(n);
    }
    conn.write_all(batch.as_bytes()).unwrap();
    let mut reader = BufReader::new(conn.try_clone().unwrap());
    for n in expected {
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        let resp = tcfft::util::json::Json::parse(line.trim()).unwrap();
        assert_eq!(resp.get("ok").and_then(|b| b.as_bool()), Some(true), "{line}");
        assert_eq!(
            resp.get("re").unwrap().as_arr().unwrap().len(),
            n,
            "replies must come back in request order"
        );
    }

    stop.store(true, std::sync::atomic::Ordering::SeqCst);
    drop(reader);
    drop(conn);
    let _ = run.join();
    svc.shutdown();
}
