//! Chaos suite: deterministic fault injection against the live
//! service. Every scenario here drives a *scheduled* fault through
//! `coordinator::faults::FaultInjector` and asserts the documented
//! recovery: panics isolate to `ExecPanic` replies, killed workers
//! respawn, expired requests shed with `DeadlineExceeded`, forced
//! evictions rebuild transparently, and chopped TCP frames reassemble.
//! The cardinal rule being tested: **no request ever hangs** — every
//! ticket resolves with a success or a coded error, bounded by
//! `wait_timeout` (a timeout in this file is a bug, not flakiness).

use std::sync::Arc;
use std::time::{Duration, Instant};

use tcfft::coordinator::faults::install_quiet_panic_hook;
use tcfft::coordinator::{
    FaultInjector, FaultPlan, FftRequest, FftService, Op, Server, ServiceConfig,
};
use tcfft::error::TcFftError;
use tcfft::plan::Direction;
use tcfft::runtime::{PlanarBatch, Runtime};
use tcfft::workload::random_signal;

fn shared_runtime() -> &'static Arc<Runtime> {
    use std::sync::OnceLock;
    static RT: OnceLock<Arc<Runtime>> = OnceLock::new();
    RT.get_or_init(|| {
        Arc::new(Runtime::load_default().expect("runtime must load without artifacts"))
    })
}

fn chaos_service(plan: FaultPlan, tweak: impl FnOnce(&mut ServiceConfig)) -> Arc<FftService> {
    install_quiet_panic_hook();
    let mut cfg = ServiceConfig {
        faults: Arc::new(FaultInjector::new(plan)),
        ..ServiceConfig::default()
    };
    tweak(&mut cfg);
    Arc::new(FftService::start(Arc::clone(shared_runtime()), cfg))
}

fn fwd_req(n: usize, seed: u64) -> FftRequest {
    let sig = random_signal(n, seed);
    FftRequest {
        op: Op::Fft1d { n },
        algo: "tc".into(),
        direction: Direction::Forward,
        input: PlanarBatch::from_complex(&sig, vec![n]),
    }
}

fn real_row(n: usize, seed: u64) -> PlanarBatch {
    let sig: Vec<f32> = random_signal(n, seed).iter().map(|c| c.re).collect();
    PlanarBatch::from_real(&sig, vec![n])
}

/// The headline soak: 64 clients push 512 convolve requests through a
/// service scheduled to panic inside every 2nd batch execution, capped
/// at 100 injected panics. Every request must resolve — success or a
/// coded error — with zero hangs, and the `exec_panics` metric must
/// equal the injector's own exact count (100: 256 fire candidates,
/// limit-capped).
#[test]
fn soak_64_clients_through_100_injected_panics_without_hangs() {
    let n = 256;
    let svc = chaos_service(
        FaultPlan {
            panic_every: 2,
            panic_key_pattern: "conv:".into(),
            panic_limit: 100,
            ..FaultPlan::default()
        },
        |cfg| cfg.large_batch = 1, // one request per batch: 512 batches exactly
    );
    svc.register_filter_bank("chaos", n, &[vec![0.25f32, 0.5, 0.25]], "tc")
        .unwrap();

    let per_client = 8u64;
    let handles: Vec<_> = (0..64u64)
        .map(|c| {
            let svc = Arc::clone(&svc);
            std::thread::spawn(move || {
                let (mut ok, mut panicked) = (0u64, 0u64);
                for i in 0..per_client {
                    let t = svc
                        .submit_convolve("chaos", real_row(n, c * 1000 + i))
                        .expect("submission itself never fails under exec faults");
                    // the no-hang contract: a generous bound that only
                    // trips if a reply channel was dropped on the floor
                    match t.wait_timeout(Duration::from_secs(30)) {
                        Ok(out) => {
                            assert_eq!(out.shape, vec![1, 1, n]);
                            ok += 1;
                        }
                        Err(TcFftError::ExecPanic(msg)) => {
                            assert!(
                                msg.contains("chaos-injected"),
                                "ExecPanic must carry the injected payload, got: {msg}"
                            );
                            panicked += 1;
                        }
                        Err(e) => panic!("client {c} got unexpected error: {e}"),
                    }
                }
                (ok, panicked)
            })
        })
        .collect();
    let (mut ok, mut panicked) = (0u64, 0u64);
    for h in handles {
        let (o, p) = h.join().expect("client thread must survive injected panics");
        ok += o;
        panicked += p;
    }

    let total = 64 * per_client;
    assert_eq!(ok + panicked, total, "every request resolved exactly once");
    let faults = svc.faults();
    assert_eq!(faults.panics_injected(), 100, "512 batches, every 2nd, capped at 100");
    assert_eq!(panicked, 100, "each 1-member batch maps one panic to one ExecPanic reply");
    let m = svc.metrics();
    assert_eq!(
        m.exec_panics.load(std::sync::atomic::Ordering::Relaxed),
        faults.panics_injected(),
        "exec_panics metric must match the injection plan exactly"
    );
    assert_eq!(m.errors_for("exec_panic"), 100);
    let snap = m.snapshot();
    assert_eq!(snap.get("completed").unwrap().as_i64(), Some(ok as i64));
    assert_eq!(snap.get("failed").unwrap().as_i64(), Some(100));
    svc.shutdown();
}

/// A panic in one batch must fan the SAME coded error out to every
/// batchmate — the rows rode the same engine call, so they share its
/// fate, but their reply channels must all fire.
#[test]
fn batchmates_of_a_panicked_batch_all_get_exec_panic() {
    let n = 256;
    let svc = chaos_service(
        FaultPlan {
            panic_every: 1,
            panic_key_pattern: "conv:".into(),
            ..FaultPlan::default()
        },
        |cfg| {
            cfg.inline_exec = false; // batch runs on an exec worker
            cfg.max_wait = Duration::from_secs(3600); // flush on full only
            cfg.large_batch = 4;
        },
    );
    svc.register_filter_bank("mates", n, &[vec![1.0f32, -1.0]], "tc")
        .unwrap();
    let tickets: Vec<_> = (0..4)
        .map(|i| svc.submit_convolve("mates", real_row(n, i)).unwrap())
        .collect();
    for t in tickets {
        match t.wait_timeout(Duration::from_secs(10)) {
            Err(TcFftError::ExecPanic(_)) => {}
            other => panic!("batchmate expected ExecPanic, got {other:?}"),
        }
    }
    let m = svc.metrics();
    assert_eq!(m.exec_panics.load(std::sync::atomic::Ordering::Relaxed), 1);
    assert_eq!(m.errors_for("exec_panic"), 4, "one panic, four member replies");
    svc.shutdown();
}

/// Killing the exec worker OUTSIDE the isolation boundary must fire
/// the supervisor: the dead worker is respawned (`worker_restarts`)
/// and the service keeps answering requests throughout.
#[test]
fn supervisor_respawns_killed_workers_and_service_keeps_serving() {
    let svc = chaos_service(
        FaultPlan {
            kill_worker_every: 1,
            kill_worker_limit: 3,
            ..FaultPlan::default()
        },
        |cfg| {
            cfg.inline_exec = false; // batches must run on killable workers
            cfg.shards = 1;
            cfg.exec_threads = 1;
        },
    );
    let n = 1024;
    for i in 0..10u64 {
        let out = svc
            .submit(fwd_req(n, i))
            .unwrap()
            .wait_timeout(Duration::from_secs(30))
            .expect("requests must keep completing across worker kills");
        assert_eq!(out.shape, vec![1, n]);
    }
    let faults = svc.faults();
    assert_eq!(faults.kills_injected(), 3, "kill schedule: first 3 worker batches");
    // the supervisor processes obituaries asynchronously; give it a
    // bounded window to log the last respawn
    let m = svc.metrics();
    let deadline = Instant::now() + Duration::from_secs(5);
    while m.worker_restarts.load(std::sync::atomic::Ordering::Relaxed) < 3 {
        assert!(Instant::now() < deadline, "supervisor never logged 3 respawns");
        std::thread::sleep(Duration::from_millis(10));
    }
    assert_eq!(
        m.worker_restarts.load(std::sync::atomic::Ordering::Relaxed),
        faults.kills_injected(),
        "worker_restarts must match the injection plan"
    );
    let snap = m.snapshot();
    assert_eq!(snap.get("completed").unwrap().as_i64(), Some(10));
    assert_eq!(snap.get("failed").unwrap().as_i64(), Some(0));
    svc.shutdown(); // must join every worker generation cleanly
}

/// Flush-time shedding: a request parked past its deadline (batch
/// never fills, `max_wait` is an hour) is answered `DeadlineExceeded`
/// by the flusher's shed scan — not held until shutdown.
#[test]
fn parked_request_past_deadline_is_shed_at_flush_time() {
    let n = 256;
    let svc = chaos_service(FaultPlan::default(), |cfg| {
        cfg.inline_exec = false;
        cfg.max_wait = Duration::from_secs(3600);
        cfg.large_batch = 4; // a single request never fills the batch
        cfg.request_deadline = Some(Duration::from_millis(50));
    });
    svc.register_filter_bank("shed", n, &[vec![1.0f32]], "tc").unwrap();
    let t = svc.submit_convolve("shed", real_row(n, 1)).unwrap();
    match t.wait_timeout(Duration::from_secs(5)) {
        Err(TcFftError::DeadlineExceeded) => {}
        other => panic!("expected DeadlineExceeded from the shed scan, got {other:?}"),
    }
    let m = svc.metrics();
    assert!(m.deadline_shed.load(std::sync::atomic::Ordering::Relaxed) >= 1);
    assert!(m.errors_for("deadline_exceeded") >= 1);
    svc.shutdown();
}

/// Pre-execution shedding: a batch flushed in time but picked up late
/// (the worker is stuck behind an injected 200 ms delay) must shed its
/// now-expired members instead of executing them past the deadline.
#[test]
fn batch_picked_up_past_deadline_is_shed_before_execution() {
    let n = 256;
    let svc = chaos_service(
        FaultPlan {
            exec_delay: Duration::from_millis(200),
            exec_delay_prob: 1.0,
            ..FaultPlan::default()
        },
        |cfg| {
            cfg.inline_exec = false;
            cfg.shards = 1;
            cfg.exec_threads = 1; // one worker: batch B queues behind A's delay
            cfg.large_batch = 1;
            cfg.request_deadline = Some(Duration::from_millis(80));
        },
    );
    svc.register_filter_bank("late", n, &[vec![1.0f32]], "tc").unwrap();
    // A flushes immediately and starts its 200 ms injected delay; its
    // shed check already passed, so it completes (late replies are
    // delivered, not dropped)
    let ta = svc.submit_convolve("late", real_row(n, 1)).unwrap();
    // B flushes right behind A but is not picked up until ~200 ms — by
    // then its 80 ms deadline is gone, so run_batch sheds it up front
    let tb = svc.submit_convolve("late", real_row(n, 2)).unwrap();
    assert!(ta.wait_timeout(Duration::from_secs(10)).is_ok(), "A passed its shed check");
    match tb.wait_timeout(Duration::from_secs(10)) {
        Err(TcFftError::DeadlineExceeded) => {}
        other => panic!("expected pre-exec shed of B, got {other:?}"),
    }
    let faults = svc.faults();
    assert!(faults.delays_injected() >= 1, "the delay fault must have fired");
    assert!(
        svc.metrics().deadline_shed.load(std::sync::atomic::Ordering::Relaxed) >= 1,
        "pre-exec shed must count in deadline_shed"
    );
    svc.shutdown();
}

/// Forced LRU evictions every single batch must never surface to
/// clients: direct plans rebuild from the registry on the next submit,
/// and the eviction shows up only in the cache counters.
#[test]
fn forced_evictions_every_batch_stay_invisible_to_clients() {
    let svc = chaos_service(FaultPlan { evict_every: 1, ..FaultPlan::default() }, |_| {});
    let n = 1024;
    for i in 0..12u64 {
        let out = svc
            .submit(fwd_req(n, i))
            .unwrap()
            .wait_timeout(Duration::from_secs(30))
            .expect("eviction chaos must not fail requests");
        assert_eq!(out.shape, vec![1, n]);
    }
    let faults = svc.faults();
    assert!(faults.evicts_forced() >= 12, "every executed batch forces one eviction");
    let m = svc.metrics();
    assert!(
        m.plan_cache.evictions() >= 1,
        "forced evictions must register in the plan-cache counters"
    );
    let snap = m.snapshot();
    assert_eq!(snap.get("completed").unwrap().as_i64(), Some(12));
    assert_eq!(snap.get("failed").unwrap().as_i64(), Some(0));
    svc.shutdown();
}

/// The TCP acceptance scenario: a client pipelines requests through a
/// service scheduled to panic on its first executed batch, with every
/// reply frame chopped into two partial writes. All replies must
/// arrive on `\n` framing, in order, each either `ok` or carrying a
/// stable `"code"` — and at least one must be the `exec_panic` the
/// schedule guarantees.
#[test]
fn tcp_pipeline_through_a_panic_gets_coded_error_lines() {
    use std::io::{BufRead, BufReader, Write};
    let n = 256;
    let svc = chaos_service(
        FaultPlan {
            panic_every: 1,
            panic_limit: 1, // exactly the first executed batch panics
            chop_prob: 1.0, // every reply frame goes out in two writes
            ..FaultPlan::default()
        },
        |_| {},
    );
    let server = Server::bind("127.0.0.1:0", Arc::clone(&svc)).unwrap();
    let addr = server.local_addr().unwrap();
    let stop = server.stop_handle();
    let run = std::thread::spawn(move || server.run());

    let mut conn = std::net::TcpStream::connect(addr).unwrap();
    conn.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
    let mut batch = String::new();
    for i in 0..3u64 {
        let sig = random_signal(n, i);
        let re: Vec<String> = sig.iter().map(|c| format!("{:.4}", c.re)).collect();
        let im: Vec<String> = sig.iter().map(|c| format!("{:.4}", c.im)).collect();
        batch.push_str(&format!(
            "{{\"op\":\"fft1d\",\"n\":{n},\"re\":[{}],\"im\":[{}]}}\n",
            re.join(","),
            im.join(",")
        ));
    }
    conn.write_all(batch.as_bytes()).unwrap();
    let mut reader = BufReader::new(conn.try_clone().unwrap());
    let mut oks = 0;
    let mut exec_panics = 0;
    for _ in 0..3 {
        let mut line = String::new();
        reader
            .read_line(&mut line)
            .expect("every pipelined request must get a reply line within the deadline");
        let resp = tcfft::util::json::Json::parse(line.trim()).unwrap();
        match resp.get("ok").and_then(|b| b.as_bool()) {
            Some(true) => oks += 1,
            _ => {
                let code = resp
                    .get("code")
                    .and_then(|c| c.as_str())
                    .expect("error lines must carry a stable code");
                assert_eq!(code, "exec_panic", "{line}");
                assert!(
                    resp.get("error").and_then(|e| e.as_str()).unwrap().contains("isolated"),
                    "{line}"
                );
                exec_panics += 1;
            }
        }
    }
    // the panicked batch held 1..=3 of the pipelined requests; however
    // it sliced, every request resolved and the panic surfaced
    assert!(exec_panics >= 1, "the scheduled panic must reach the client as a coded line");
    assert_eq!(oks + exec_panics, 3);
    let faults = svc.faults();
    assert_eq!(faults.panics_injected(), 1);
    assert!(faults.chops_injected() >= 3, "every reply frame was chop-scheduled");

    stop.store(true, std::sync::atomic::Ordering::SeqCst);
    drop(reader);
    drop(conn);
    let _ = run.join();
    svc.shutdown();
}
