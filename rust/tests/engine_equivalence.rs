//! Bit-exactness contract of the batch-major parallel execution engine.
//!
//! The engine chunks batch rows across the thread pool; rows are
//! independent, so chunking must never change a single output bit.
//! This suite drives the full variant space — 1D and 2D, forward and
//! inverse, `tc`/`tc_split`/`tc_ec`/`r2`, batches {1, 3, 32} (3 is a
//! non-power-of-two batch that forces uneven chunk splits) — and
//! asserts:
//!
//! * parallel engine == serial engine, **bit for bit**;
//! * `tc_split` == the pre-PR [`ReferenceInterpreter`], bit for bit
//!   (the de-fused ablation kernels were never re-associated), and
//!   `tc_ec` == the reference bit for bit (both engines run the same
//!   compensated kernel, whose float-op order is shared by
//!   construction);
//! * `tc`/`r2` track the reference within a tight rel-RMSE bound (the
//!   fused kernels change only f32-level association — every fp16
//!   rounding point is identical, so outputs agree far below the fp16
//!   noise floor).

use tcfft::error::relative_rmse;
use tcfft::hp::complex::widen;
use tcfft::hp::C32;
use tcfft::runtime::simd;
use tcfft::runtime::{Backend, CpuInterpreter, PlanarBatch, ReferenceInterpreter, VariantMeta};
use tcfft::workload::random_signal;

fn meta_1d(algo: &str, n: usize, batch: usize, inverse: bool) -> VariantMeta {
    let d = if inverse { "inv" } else { "fwd" };
    VariantMeta {
        key: format!("eq_fft1d_{algo}_n{n}_b{batch}_{d}"),
        file: std::path::PathBuf::new(),
        op: "fft1d".to_string(),
        algo: algo.to_string(),
        n,
        nx: 0,
        ny: 0,
        batch,
        inverse,
        input_shape: vec![batch, n],
        stages: Vec::new(),
        flops_per_seq: 0.0,
        hbm_bytes_per_seq: 0.0,
        radix2_equiv_flops: 0.0,
    }
}

fn meta_2d(algo: &str, nx: usize, ny: usize, batch: usize, inverse: bool) -> VariantMeta {
    let d = if inverse { "inv" } else { "fwd" };
    VariantMeta {
        key: format!("eq_fft2d_{algo}_nx{nx}x{ny}_b{batch}_{d}"),
        file: std::path::PathBuf::new(),
        op: "fft2d".to_string(),
        algo: algo.to_string(),
        n: 0,
        nx,
        ny,
        batch,
        inverse,
        input_shape: vec![batch, nx, ny],
        stages: Vec::new(),
        flops_per_seq: 0.0,
        hbm_bytes_per_seq: 0.0,
        radix2_equiv_flops: 0.0,
    }
}

fn random_batch(seq: usize, batch: usize, shape: Vec<usize>, seed: u64) -> PlanarBatch {
    let x: Vec<C32> = (0..batch)
        .flat_map(|b| random_signal(seq, seed + b as u64))
        .collect();
    PlanarBatch::from_complex(&x, shape)
}

fn assert_bit_identical(a: &PlanarBatch, b: &PlanarBatch, what: &str) {
    assert_eq!(a.shape, b.shape, "{what}: shape");
    for i in 0..a.len() {
        assert_eq!(
            a.re[i].to_bits(),
            b.re[i].to_bits(),
            "{what}: re[{i}] {} vs {}",
            a.re[i],
            b.re[i]
        );
        assert_eq!(
            a.im[i].to_bits(),
            b.im[i].to_bits(),
            "{what}: im[{i}] {} vs {}",
            a.im[i],
            b.im[i]
        );
    }
}

/// Run one variant through the serial engine, the parallel engine and
/// the pre-PR reference, and check all three contracts.
fn check(meta: &VariantMeta, input: PlanarBatch, threads: usize) {
    let serial = CpuInterpreter::with_threads(1);
    let parallel = CpuInterpreter::with_threads(threads);
    let reference = ReferenceInterpreter::new();

    let (y_ser, _) = serial.execute(meta, input.clone()).unwrap();
    let (y_par, _) = parallel.execute(meta, input.clone()).unwrap();
    let (y_ref, _) = reference.execute(meta, input).unwrap();

    assert_bit_identical(&y_ser, &y_par, &format!("{} serial vs parallel", meta.key));

    if meta.algo == "tc_split" || meta.algo == "tc_ec" {
        // the de-fused ablation kernel keeps the pre-PR float-op
        // order; the ec kernel is shared between engines outright
        assert_bit_identical(&y_ser, &y_ref, &format!("{} engine vs reference", meta.key));
    } else {
        let err = relative_rmse(&widen(&y_ref.to_complex()), &widen(&y_ser.to_complex()));
        assert!(err < 2e-3, "{}: engine vs reference rmse {err}", meta.key);
    }
}

#[test]
fn fft1d_all_algos_dirs_batches() {
    for algo in ["tc", "tc_split", "tc_ec", "r2"] {
        for inverse in [false, true] {
            for batch in [1usize, 3, 32] {
                let meta = meta_1d(algo, 1024, batch, inverse);
                let input = random_batch(1024, batch, vec![batch, 1024], 11);
                // 5 workers over 32 rows -> chunks of 7,7,7,7,4
                check(&meta, input, 5);
            }
        }
    }
}

#[test]
fn fft1d_nonpow2_batch_chunk_edge() {
    // batch 3 at n=4096 crosses the parallel work threshold, so three
    // single-row chunks really run on the pool (threads > rows edge)
    for algo in ["tc", "tc_split", "tc_ec", "r2"] {
        let meta = meta_1d(algo, 4096, 3, false);
        let input = random_batch(4096, 3, vec![3, 4096], 23);
        check(&meta, input, 4);
    }
}

#[test]
fn fft2d_all_algos_dirs_batches() {
    for algo in ["tc", "tc_split", "tc_ec", "r2"] {
        for inverse in [false, true] {
            for batch in [1usize, 3, 32] {
                let meta = meta_2d(algo, 64, 64, batch, inverse);
                let input = random_batch(64 * 64, batch, vec![batch, 64, 64], 37);
                check(&meta, input, 5);
            }
        }
    }
}

#[test]
fn contracts_hold_under_every_forced_simd_path() {
    // parallel == serial == reference must survive the SIMD kernels:
    // the stage dispatcher hands whole chunks to the vector panels, and
    // those are bitwise-identical to scalar (tests/simd_equivalence.rs),
    // so forcing a path may not move a single contract. The reference
    // engine never routes through SIMD — on `tc_split`/`tc_ec` the
    // bit-identity check below therefore pins vector vs scalar codec
    // output end to end. Restores auto selection when done; concurrent
    // tests are immune to the flip by the same bitwise contract.
    let paths = simd::available_vector_paths();
    if paths.is_empty() {
        eprintln!("note: forced-SIMD contract test skipped — no vector path on this CPU/build");
        return;
    }
    for path in paths {
        simd::force(Some(path)).unwrap();
        for algo in ["tc", "tc_split", "tc_ec"] {
            let meta = meta_1d(algo, 1024, 3, false);
            let input = random_batch(1024, 3, vec![3, 1024], 71);
            check(&meta, input, 4);
            let meta = meta_2d(algo, 64, 64, 3, true);
            let input = random_batch(64 * 64, 3, vec![3, 64, 64], 83);
            check(&meta, input, 4);
        }
    }
    simd::force(None).unwrap();
}

#[test]
fn engine_is_deterministic_across_repeats() {
    // same input, same backend, repeated runs (scratch arena reuse,
    // warm cache) must be bit-identical
    let meta = meta_1d("tc", 2048, 6, false);
    let be = CpuInterpreter::with_threads(4);
    let input = random_batch(2048, 6, vec![6, 2048], 53);
    let (first, _) = be.execute(&meta, input.clone()).unwrap();
    for _ in 0..3 {
        let (again, _) = be.execute(&meta, input.clone()).unwrap();
        assert_bit_identical(&first, &again, "repeat determinism");
    }
}
