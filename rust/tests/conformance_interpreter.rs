//! Conformance of the `CpuInterpreter` backend against the host f64
//! oracles, Table-4 style: forward 1D FFTs for every power-of-two size
//! 2^4..=2^16 at request batches {1, 4, 32}, checked by relative RMSE
//! (fp16 inputs, f32 accumulation), plus inverse round trips and 2D.
//!
//! Oracle strategy: sizes <= 512 are checked directly against the
//! O(N^2) DFT definition (`fft::refdft`); larger sizes use the f64
//! radix-2 FFT, itself validated against `refdft` in its own tests
//! (and cross-checked here at the small sizes).
//!
//! Tolerance: the numpy model of this exact pipeline (fp16-rounded
//! tables, fp16 intermediate stores) measures relative RMSE between
//! 1.8e-4 (2^4) and 5.5e-4 (2^16) for uniform [-1,1) inputs; 5e-3
//! leaves ~10x margin while still failing on any structural error.

use std::sync::{Arc, OnceLock};

use tcfft::error::{relative_error, relative_rmse};
use tcfft::fft::{radix2, refdft};
use tcfft::hp::{C32, C64};
use tcfft::plan::{Direction, Plan};
use tcfft::runtime::{PlanarBatch, Registry, Runtime};
use tcfft::workload::random_signal;

const RMSE_TOL: f64 = 5e-3;

fn runtime() -> &'static Runtime {
    static RT: OnceLock<Runtime> = OnceLock::new();
    RT.get_or_init(|| {
        Runtime::with_backend(
            Arc::new(Registry::synthesize()),
            Box::new(tcfft::runtime::CpuInterpreter::new()),
        )
    })
}

fn widen(x: &[C32]) -> Vec<C64> {
    x.iter().map(|c| C64::new(c.re as f64, c.im as f64)).collect()
}

/// f64 oracle on the fp16-quantized input (what the device sees).
fn oracle_rows(quantized: &[C64], batch: usize, n: usize, inverse: bool) -> Vec<C64> {
    let mut out = Vec::with_capacity(batch * n);
    for b in 0..batch {
        let row = &quantized[b * n..(b + 1) * n];
        if n <= 512 {
            out.extend(refdft::dft(row, inverse));
        } else {
            out.extend(radix2::fft_vec(row, inverse));
        }
    }
    out
}

fn check_forward(n: usize, batch: usize, seed: u64) {
    let rt = runtime();
    let plan = Plan::fft1d(&rt.registry, n, batch).unwrap();
    let x: Vec<C32> = (0..batch)
        .flat_map(|b| random_signal(n, seed + b as u64))
        .collect();
    let input = PlanarBatch::from_complex(&x, vec![batch, n]);
    let out = plan.execute(rt, input.clone()).unwrap();
    assert_eq!(out.shape, vec![batch, n]);

    let q = widen(&input.quantize_f16().to_complex());
    let want = oracle_rows(&q, batch, n, false);
    let got = widen(&out.to_complex());
    for b in 0..batch {
        let (lo, hi) = (b * n, (b + 1) * n);
        let rmse = relative_rmse(&want[lo..hi], &got[lo..hi]);
        assert!(
            rmse < RMSE_TOL,
            "n={n} batch={batch} row={b}: relative RMSE {rmse:.3e} over tol {RMSE_TOL:.1e}"
        );
        // paper-band sanity on the eq.-5 style metric as well
        let rel = relative_error(&want[lo..hi], &got[lo..hi]);
        assert!(rel < 2e-2, "n={n} row={b}: mean relative error {rel:.3e}");
    }
}

#[test]
fn forward_1d_all_sizes_batch_1() {
    for t in 4..=16usize {
        check_forward(1 << t, 1, 0xA000 + t as u64);
    }
}

#[test]
fn forward_1d_all_sizes_batch_4() {
    for t in 4..=16usize {
        check_forward(1 << t, 4, 0xB000 + t as u64);
    }
}

#[test]
fn forward_1d_all_sizes_batch_32() {
    for t in 4..=16usize {
        check_forward(1 << t, 32, 0xC000 + t as u64);
    }
}

#[test]
fn small_sizes_match_the_dft_definition_directly() {
    // belt-and-braces: the oracle dispatch above uses refdft for these,
    // but assert the direct comparison explicitly at every small size
    let rt = runtime();
    for t in 4..=9usize {
        let n = 1 << t;
        let plan = Plan::fft1d(&rt.registry, n, 1).unwrap();
        let x = random_signal(n, 0xD000 + t as u64);
        let input = PlanarBatch::from_complex(&x, vec![1, n]);
        let out = plan.execute(rt, input.clone()).unwrap();
        let want = refdft::dft(&widen(&input.quantize_f16().to_complex()), false);
        let rmse = relative_rmse(&want, &widen(&out.to_complex()));
        assert!(rmse < RMSE_TOL, "n={n}: rmse vs refdft {rmse:.3e}");
    }
}

#[test]
fn inverse_round_trip_1d() {
    // forward then unnormalized inverse, scaled back by 1/N, recovers
    // the quantized input. Sizes stay <= 2^14: at 2^16 the unnormalized
    // inverse peaks above fp16 max (65504) for unit-scale inputs — a
    // real dynamic-range property of half precision, not a bug.
    let rt = runtime();
    for t in [4usize, 8, 12, 14] {
        let n = 1 << t;
        let fwd = Plan::fft1d(&rt.registry, n, 4).unwrap();
        let inv = Plan::fft1d_algo(&rt.registry, n, 4, "tc", Direction::Inverse).unwrap();
        let x: Vec<C32> = (0..4)
            .flat_map(|b| random_signal(n, 0xE000 + (t * 10 + b) as u64))
            .collect();
        let input = PlanarBatch::from_complex(&x, vec![4, n]);
        let spec = fwd.execute(rt, input.clone()).unwrap();
        let mut back = inv.execute(rt, spec).unwrap();
        for v in back.re.iter_mut().chain(back.im.iter_mut()) {
            *v /= n as f32;
        }
        let want = widen(&input.quantize_f16().to_complex());
        let got = widen(&back.to_complex());
        let rmse = relative_rmse(&want, &got);
        assert!(rmse < 2.0 * RMSE_TOL, "n={n}: round-trip rmse {rmse:.3e}");
    }
}

#[test]
fn inverse_matches_conjugate_oracle() {
    // the inverse artifact itself (not just the round trip) must match
    // the f64 inverse DFT (unnormalized, cuFFT convention)
    let rt = runtime();
    let n = 256;
    let inv = Plan::fft1d_algo(&rt.registry, n, 4, "tc", Direction::Inverse).unwrap();
    let x = random_signal(n, 0xF00D);
    let input = PlanarBatch::from_complex(&x, vec![1, n]);
    let out = inv.execute(rt, input.clone()).unwrap();
    let want = refdft::dft(&widen(&input.quantize_f16().to_complex()), true);
    let rmse = relative_rmse(&want, &widen(&out.to_complex()));
    assert!(rmse < RMSE_TOL, "inverse rmse {rmse:.3e}");
}

#[test]
fn r2_baseline_agrees_with_tc() {
    // both algorithms compute the same transform within fp16 tolerance
    let rt = runtime();
    for n in [256usize, 4096] {
        let x: Vec<C32> = (0..4).flat_map(|b| random_signal(n, 77 + b as u64)).collect();
        let input = PlanarBatch::from_complex(&x, vec![4, n]);
        let tc = Plan::fft1d_algo(&rt.registry, n, 4, "tc", Direction::Forward).unwrap();
        let r2 = Plan::fft1d_algo(&rt.registry, n, 4, "r2", Direction::Forward).unwrap();
        let a = widen(&tc.execute(rt, input.clone()).unwrap().to_complex());
        let b = widen(&r2.execute(rt, input).unwrap().to_complex());
        let rmse = relative_rmse(&a, &b);
        assert!(rmse < 2.0 * RMSE_TOL, "n={n}: tc vs r2 rmse {rmse:.3e}");
    }
}

#[test]
fn forward_2d_matches_fft2_oracle() {
    let rt = runtime();
    let (nx, ny) = (128usize, 128usize);
    let plan = Plan::fft2d(&rt.registry, nx, ny, 2).unwrap();
    let x: Vec<C32> = (0..2)
        .flat_map(|b| random_signal(nx * ny, 31 + b as u64))
        .collect();
    let input = PlanarBatch::from_complex(&x, vec![2, nx, ny]);
    let out = plan.execute(rt, input.clone()).unwrap();
    let q = widen(&input.quantize_f16().to_complex());
    let mut want = Vec::new();
    for b in 0..2 {
        let mut m = q[b * nx * ny..(b + 1) * nx * ny].to_vec();
        radix2::fft2(&mut m, nx, ny, false);
        want.extend(m);
    }
    let rmse = relative_rmse(&want, &widen(&out.to_complex()));
    assert!(rmse < RMSE_TOL, "2D rmse {rmse:.3e}");
}

#[test]
fn linearity_of_the_interpreter() {
    // FFT(a + b) == FFT(a) + FFT(b) within fp16 tolerance
    let rt = runtime();
    let n = 1024;
    let plan = Plan::fft1d(&rt.registry, n, 4).unwrap();
    let a: Vec<C32> = random_signal(n, 1).iter().map(|c| c.scale(0.5)).collect();
    let b: Vec<C32> = random_signal(n, 2).iter().map(|c| c.scale(0.5)).collect();
    let sum: Vec<C32> = a.iter().zip(&b).map(|(&x, &y)| x + y).collect();
    let run = |sig: &[C32]| {
        let input = PlanarBatch::from_complex(sig, vec![1, n]);
        widen(&plan.execute(rt, input).unwrap().to_complex())
    };
    let (fa, fb, fs) = (run(&a), run(&b), run(&sum));
    let lin: Vec<C64> = fa.iter().zip(&fb).map(|(&x, &y)| x + y).collect();
    let rmse = relative_rmse(&fs, &lin);
    assert!(rmse < 2.0 * RMSE_TOL, "linearity rmse {rmse:.3e}");
}
